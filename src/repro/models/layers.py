"""Shared building blocks: norms, rotary embeddings, SwiGLU, initializers.

All layers are pure functions over parameter pytrees (dicts of arrays).
Parameters live in f32; compute happens in the caller-chosen dtype.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None
               ) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(w: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)          # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    positions: (3, ..., S) -- temporal/height/width position ids.  The
    rotary half-dim is split into `sections` (t, h, w); each section takes
    its angle from the corresponding position stream.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)          # (half,)
    angle_streams = positions[..., None].astype(jnp.float32) * freqs
    # angle_streams: (3, ..., S, half); select per-section stream
    parts = []
    start = 0
    for idx, sec in enumerate(sections):
        parts.append(angle_streams[idx][..., start:start + sec])
        start += sec
    angles = jnp.concatenate(parts, axis=-1)              # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# feed-forward
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d_model, d_ff),
            "w_up": dense_init(k2, d_model, d_ff),
            "w_down": dense_init(k3, d_ff, d_model)}


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    dtype = x.dtype
    g = x @ p["w_gate"].astype(dtype)
    u = x @ p["w_up"].astype(dtype)
    return (jax.nn.silu(g) * u) @ p["w_down"].astype(dtype)
