"""Causal LM over every assigned family: one scan-over-layers implementation.

Layer stacks are scanned (stacked parameter pytrees) so the lowered HLO is
O(1) in depth -- essential for compiling 80-layer models against a
512-device mesh.  Hybrid models (Zamba2) scan over *super-blocks*:
`attn_every` SSM layers followed by one application of the **shared**
attention block (parameters closed over, not scanned -- the architecture's
defining weight-sharing), with per-application KV caches stacked on the
super-block axis.

Modes:
  forward/loss  -- teacher-forced training (remat per layer)
  prefill       -- full-prompt pass returning the KV/SSM caches
  decode_step   -- one token against the caches
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, init_attention, make_cache
from .config import ModelConfig
from .layers import Params, dense_init, init_mlp, mlp, rmsnorm
from .moe import init_moe, moe_ffn
from .ssm import init_ssm, make_ssm_state, ssm_layer

Pytree = Any


# --------------------------------------------------------------------------
# per-layer blocks
# --------------------------------------------------------------------------

def _init_dense_layer(key, cfg: ModelConfig, d_ff: Optional[int] = None,
                      cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
         "attn": init_attention(ks[0], cfg),
         "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.n_experts and not cross:
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, d_ff or cfg.d_ff)
    if cross:
        p["ln_cross"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["cross"] = init_attention(ks[2], cfg, cross=True)
    return p


def _dense_block(p: Params, x, cfg: ModelConfig, *, positions, cache,
                 cache_index, enc_out=None, enc_pos=None, causal=True,
                 use_moe=None):
    from .attention import cross_attend

    self_cache = cache
    cross_kv = None
    if cache is not None and "ck" in cache:
        cross_kv = (cache["ck"], cache["cv"])
        self_cache = {k: v for k, v in cache.items()
                      if k in ("k", "v", "k_scale", "v_scale")}
    h, new_cache = attention(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                             cfg, positions=positions, cache=self_cache,
                             cache_index=cache_index, causal=causal)
    x = x + h
    aux = {}
    if "cross" in p and enc_out is not None:        # prefill/train: build kv
        h, ckv = attention(p["cross"], rmsnorm(p["ln_cross"], x,
                                               cfg.norm_eps),
                           cfg, positions=positions, kv_x=enc_out,
                           kv_positions=enc_pos)
        x = x + h
        if new_cache is not None:
            new_cache = {**new_cache, **ckv}
    elif "cross" in p and cross_kv is not None:     # decode: cached kv
        b = x.shape[0]
        kv_pos = jnp.broadcast_to(
            jnp.arange(cross_kv[0].shape[1], dtype=jnp.int32)[None],
            (b, cross_kv[0].shape[1]))
        h = cross_attend(p["cross"], rmsnorm(p["ln_cross"], x, cfg.norm_eps),
                         cfg, cross_kv,
                         positions if positions.ndim == 2 else positions[0],
                         kv_pos)
        x = x + h
        new_cache = {**new_cache, "ck": cross_kv[0], "cv": cross_kv[1]}
    moe_here = use_moe if use_moe is not None else ("moe" in p)
    if moe_here:
        h, aux = moe_ffn(p["moe"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    else:
        h = mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + h, new_cache, aux


def _init_ssm_layer(key, cfg: ModelConfig) -> Params:
    return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ssm": init_ssm(key, cfg)}


def _ssm_block(p: Params, x, cfg: ModelConfig, *, state):
    h, new_state = ssm_layer(p["ssm"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                             cfg, state=state)
    return x + h, new_state


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {
        "embed": jax.random.normal(ks[0], (cfg.vocab_padded, d),
                                   jnp.float32) * 0.02,
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], d, cfg.vocab_padded, scale=0.02)

    if cfg.family == "ssm":
        p["layers"] = _stack_init(lambda k: _init_ssm_layer(k, cfg), ks[2],
                                  cfg.n_layers)
    elif cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers % cfg.attn_every
        p["layers"] = jax.vmap(
            lambda k: _stack_init(lambda kk: _init_ssm_layer(kk, cfg), k,
                                  cfg.attn_every)
        )(jax.random.split(ks[2], n_super))
        if tail:
            p["tail"] = _stack_init(lambda k: _init_ssm_layer(k, cfg),
                                    ks[3], tail)
        p["shared_attn"] = _init_dense_layer(ks[4], cfg)
    else:
        n_scanned = cfg.n_layers - cfg.first_dense_layers
        p["layers"] = _stack_init(lambda k: _init_dense_layer(k, cfg),
                                  ks[2], n_scanned)
        if cfg.first_dense_layers:
            p["first_dense"] = _stack_init(
                lambda k: _init_dense_layer(
                    k, dataclasses.replace(cfg, n_experts=0),
                    d_ff=cfg.dense_d_ff or cfg.d_ff),
                ks[3], cfg.first_dense_layers)
        if cfg.enc_dec:
            enc_cfg = dataclasses.replace(cfg, n_experts=0)
            p["encoder"] = _stack_init(
                lambda k: _init_dense_layer(k, enc_cfg), ks[5],
                cfg.n_enc_layers)
            p["enc_norm"] = jnp.ones((d,), jnp.float32)
            # decoder layers get cross-attention
            p["layers"] = _stack_init(
                lambda k: _init_dense_layer(k, cfg, cross=True), ks[2],
                cfg.n_layers)
    if cfg.frontend:
        p["frontend"] = {"proj": dense_init(ks[6], cfg.frontend_dim, d),
                         "bias": jnp.zeros((d,), jnp.float32)}
    return p


def abstract_params(cfg: ModelConfig) -> Pytree:
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------

def _embed_inputs(p: Params, cfg: ModelConfig, batch: Dict, dtype):
    tokens = batch["tokens"]
    x = jnp.take(p["embed"], tokens, axis=0).astype(dtype)
    if cfg.frontend == "vision":
        vis = batch["vision_embeds"].astype(dtype)            # (B,Fl,Fd)
        vis = vis @ p["frontend"]["proj"].astype(dtype) + \
            p["frontend"]["bias"].astype(dtype)
        x = jnp.concatenate([vis, x[:, cfg.frontend_len:]], axis=1)
    return x


def _logits(p: Params, cfg: ModelConfig, x):
    head = p["embed"].T if cfg.tie_embeddings else p["head"]
    return x @ head.astype(x.dtype)


def _positions(cfg: ModelConfig, batch: Dict, b: int, s: int):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, b, s))
    return pos


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def forward(p: Params, cfg: ModelConfig, batch: Dict, *,
            dtype=jnp.bfloat16, want_cache: bool = False, remat: bool = True,
            remat_policy: Optional[str] = None, unroll: bool = False,
            act_spec=None, return_hidden: bool = False):
    """Full-sequence pass.  Returns (logits, caches|None, aux).
    unroll=True unrolls layer scans (dry-run collective accounting).
    act_spec: PartitionSpec pinned onto the residual stream after every
    block -- P(dp, None, None) forces the FSDP (weight-gathered) layout,
    P(dp, 'model', None) forces sequence-parallel residency.
    return_hidden: skip the LM head (chunked-loss path computes it)."""
    x = _embed_inputs(p, cfg, batch, dtype)

    def pin(h):
        if act_spec is None:
            return h
        return jax.lax.with_sharding_constraint(h, act_spec)
    x = pin(x)
    b, s, _ = x.shape
    positions = _positions(cfg, batch, b, s)
    aux_sum = {"aux_loss": jnp.zeros((), jnp.float32),
               "z_loss": jnp.zeros((), jnp.float32)}

    enc_out = enc_pos = None
    if cfg.enc_dec:
        enc_out, enc_pos = _encode(p, cfg, batch, dtype, unroll=unroll)

    def maybe_remat(fn):
        if not remat:
            return fn
        policy = None
        if remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)

    caches = {}
    if cfg.family == "ssm":
        def body(carry, layer_p):
            h, st = _ssm_block(layer_p, carry, cfg, state=None)
            return pin(h), st
        x, states = jax.lax.scan(maybe_remat(body), x, p["layers"],
                                 unroll=unroll)
        caches["ssm"] = states
    elif cfg.family == "hybrid":
        def super_body(carry, layer_p):
            def inner(c, lp):
                h, st = _ssm_block(lp, c, cfg, state=None)
                return h, st
            h, states = jax.lax.scan(inner, carry, layer_p, unroll=unroll)
            h, att_cache, _ = _dense_block(
                p["shared_attn"], h, cfg, positions=positions, cache=None,
                cache_index=None)
            return pin(h), (states, att_cache)
        x, (states, att_caches) = jax.lax.scan(maybe_remat(super_body), x,
                                               p["layers"], unroll=unroll)
        caches["ssm"], caches["attn"] = states, att_caches
        if "tail" in p:
            def tail_body(carry, lp):
                h, st = _ssm_block(lp, carry, cfg, state=None)
                return h, st
            x, tail_states = jax.lax.scan(maybe_remat(tail_body), x,
                                          p["tail"], unroll=unroll)
            caches["tail"] = tail_states
    else:
        if "first_dense" in p:
            def fd_body(carry, lp):
                h, kv, _ = _dense_block(lp, carry, cfg, positions=positions,
                                        cache=None, cache_index=None,
                                        use_moe=False)
                return h, kv
            x, fd_caches = jax.lax.scan(maybe_remat(fd_body), x,
                                        p["first_dense"], unroll=unroll)
            caches["first_dense"] = fd_caches

        def body(carry, layer_p):
            h, a = carry
            h, kv, aux = _dense_block(layer_p, h, cfg, positions=positions,
                                      cache=None, cache_index=None,
                                      enc_out=enc_out, enc_pos=enc_pos)
            for k2 in a:
                a = dict(a, **{k2: a[k2] + aux.get(k2, 0.0)})
            return (pin(h), a), kv
        (x, aux_sum), kv_caches = jax.lax.scan(maybe_remat(body),
                                               (x, aux_sum), p["layers"],
                                               unroll=unroll)
        caches["attn"] = kv_caches

    x = rmsnorm(p["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, (caches if want_cache else None), aux_sum
    logits = _logits(p, cfg, x)
    return logits, (caches if want_cache else None), aux_sum


def _encode(p: Params, cfg: ModelConfig, batch: Dict, dtype,
            unroll: bool = False):
    frames = batch["enc_frames"].astype(dtype)               # (B,Se,Fd)
    h = frames @ p["frontend"]["proj"].astype(dtype) + \
        p["frontend"]["bias"].astype(dtype)
    b, se, _ = h.shape
    pos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32)[None], (b, se))

    def body(carry, lp):
        x, kv, _ = _dense_block(lp, carry, cfg, positions=pos, cache=None,
                                cache_index=None, causal=False, use_moe=False)
        return x, None
    h, _ = jax.lax.scan(jax.checkpoint(body), h, p["encoder"],
                        unroll=unroll)
    return rmsnorm(p["enc_norm"], h, cfg.norm_eps), pos


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------

def _nll(logits, labels, vocab):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    # one-hot contraction (gather-free: TPU/GSPMD friendly on sharded vocab)
    gold = jnp.einsum("bsv,bsv->bs", logits.astype(jnp.float32),
                      jax.nn.one_hot(labels, vocab, dtype=jnp.float32))
    return lse - gold


def loss_fn(p: Params, cfg: ModelConfig, batch: Dict, *, dtype=jnp.bfloat16,
            remat_policy: Optional[str] = None, unroll: bool = False,
            act_spec=None, loss_chunks: int = 0, remat: bool = True):
    """loss_chunks > 0 streams the LM head + softmax over sequence chunks
    so the (B, S, V) logits tensor never materializes (memory-term
    optimization; see EXPERIMENTS.md §Perf)."""
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if loss_chunks:
        hidden, _, aux = forward(p, cfg, batch, dtype=dtype, remat=remat,
                                 remat_policy=remat_policy, unroll=unroll,
                                 act_spec=act_spec, return_hidden=True)
        b, s, d = hidden.shape
        assert s % loss_chunks == 0, (s, loss_chunks)
        c = s // loss_chunks
        if mask is None:
            mask = jnp.ones((b, s), jnp.float32)
        head = (p["embed"].T if cfg.tie_embeddings else p["head"])
        head = head.astype(hidden.dtype)
        xs = (hidden.reshape(b, loss_chunks, c, d).swapaxes(0, 1),
              labels.reshape(b, loss_chunks, c).swapaxes(0, 1),
              mask.astype(jnp.float32).reshape(
                  b, loss_chunks, c).swapaxes(0, 1))

        def body(carry, xsc):
            tot, cnt = carry
            hc, lc, mc = xsc
            nll_c = _nll(hc @ head, lc, cfg.vocab_padded)
            return (tot + (nll_c * mc).sum(), cnt + mc.sum()), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            xs)
        nll_mean = tot / jnp.maximum(cnt, 1.0)
        loss = nll_mean + aux["aux_loss"] + aux["z_loss"]
        return loss, {"loss": loss, "nll": nll_mean, **aux}
    logits, _, aux = forward(p, cfg, batch, dtype=dtype, remat=remat,
                             remat_policy=remat_policy, unroll=unroll,
                             act_spec=act_spec)
    nll = _nll(logits, labels, cfg.vocab_padded)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = loss + aux["aux_loss"] + aux["z_loss"]
    metrics = {"loss": loss, "nll": (nll * mask).sum() / jnp.maximum(
        mask.sum(), 1.0), **aux}
    return loss, metrics


# --------------------------------------------------------------------------
# caches / decode
# --------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16, enc_len: Optional[int] = None) -> Dict:
    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None],
                                                       (n, *x.shape)), tree)
    if cfg.family == "ssm":
        return {"ssm": stack(make_ssm_state(cfg, batch), cfg.n_layers)}
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers % cfg.attn_every
        c = {"ssm": stack(stack(make_ssm_state(cfg, batch), cfg.attn_every),
                          n_super),
             "attn": stack(make_cache(cfg, batch, max_len, dtype), n_super)}
        if tail:
            c["tail"] = stack(make_ssm_state(cfg, batch), tail)
        return c
    base = make_cache(cfg, batch, max_len, dtype)
    if cfg.enc_dec:
        se = enc_len or max_len
        base = {**base,
                "ck": jnp.zeros((batch, se, cfg.n_kv_heads, cfg.head_dim),
                                dtype),
                "cv": jnp.zeros((batch, se, cfg.n_kv_heads, cfg.head_dim),
                                dtype)}
    c = {"attn": stack(base, cfg.n_layers - cfg.first_dense_layers)}
    if cfg.first_dense_layers:
        c["first_dense"] = stack(make_cache(cfg, batch, max_len, dtype),
                                 cfg.first_dense_layers)
    return c


def pad_caches(caches: Dict, max_len: int) -> Dict:
    """Grow prefill caches (seq = prompt len) to the serving max_len."""
    def pad(path, x):
        names = [p.key for p in path if hasattr(p, "key")]
        if names and names[-1] in ("k", "v", "latent", "k_rope"):
            axis = x.ndim - (3 if names[-1] in ("latent", "k_rope") else 4) + 1
            pad_amt = max_len - x.shape[axis]
            if pad_amt > 0:
                widths = [(0, 0)] * x.ndim
                widths[axis] = (0, pad_amt)
                return jnp.pad(x, widths)
        return x
    return jax.tree_util.tree_map_with_path(pad, caches)


def decode_step(p: Params, cfg: ModelConfig, tokens, caches: Dict,
                cache_index, *, dtype=jnp.bfloat16,
                batch_extras: Optional[Dict] = None, unroll: bool = False):
    """One decode step.  tokens: (B,1); cache_index: scalar int32.
    Enc-dec cross K/V comes from the caches (filled by prefill)."""
    x = jnp.take(p["embed"], tokens, axis=0).astype(dtype)
    b = tokens.shape[0]
    pos = jnp.full((b, 1), cache_index, jnp.int32)
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, b, 1))

    new_caches = dict(caches)
    if cfg.family == "ssm":
        def body(carry, xs):
            lp, st = xs
            h, st2 = _ssm_block(lp, carry, cfg, state=st)
            return h, st2
        x, states = jax.lax.scan(body, x, (p["layers"], caches["ssm"]),
                                 unroll=unroll)
        new_caches["ssm"] = states
    elif cfg.family == "hybrid":
        def super_body(carry, xs):
            lp, st, kv = xs
            def inner(c, ys):
                ilp, ist = ys
                h, ist2 = _ssm_block(ilp, c, cfg, state=ist)
                return h, ist2
            h, st2 = jax.lax.scan(inner, carry, (lp, st), unroll=unroll)
            h, kv2, _ = _dense_block(p["shared_attn"], h, cfg, positions=pos,
                                     cache=kv, cache_index=cache_index)
            return h, (st2, kv2)
        x, (states, kvs) = jax.lax.scan(
            super_body, x, (p["layers"], caches["ssm"], caches["attn"]),
            unroll=unroll)
        new_caches["ssm"], new_caches["attn"] = states, kvs
        if "tail" in p:
            def tail_body(carry, xs):
                lp, st = xs
                h, st2 = _ssm_block(lp, carry, cfg, state=st)
                return h, st2
            x, ts = jax.lax.scan(tail_body, x, (p["tail"], caches["tail"]),
                                 unroll=unroll)
            new_caches["tail"] = ts
    else:
        if "first_dense" in p:
            def fd_body(carry, xs):
                lp, kv = xs
                h, kv2, _ = _dense_block(lp, carry, cfg, positions=pos,
                                         cache=kv, cache_index=cache_index,
                                         use_moe=False)
                return h, kv2
            x, fd = jax.lax.scan(fd_body, x,
                                 (p["first_dense"], caches["first_dense"]),
                                 unroll=unroll)
            new_caches["first_dense"] = fd

        def body(carry, xs):
            lp, kv = xs
            h, kv2, _ = _dense_block(lp, carry, cfg, positions=pos,
                                     cache=kv, cache_index=cache_index)
            return h, kv2
        x, kvs = jax.lax.scan(body, x, (p["layers"], caches["attn"]),
                              unroll=unroll)
        new_caches["attn"] = kvs

    x = rmsnorm(p["final_norm"], x, cfg.norm_eps)
    return _logits(p, cfg, x), new_caches


def prefill(p: Params, cfg: ModelConfig, batch: Dict, *, dtype=jnp.bfloat16,
            unroll: bool = False):
    """Prompt pass: returns last-position logits + caches (KV in bf16)."""
    logits, caches, _ = forward(p, cfg, batch, dtype=dtype, want_cache=True,
                                remat=False, unroll=unroll)
    return logits[:, -1:], caches
