"""Model configuration schema covering every assigned architecture family.

One frozen dataclass describes dense / MoE / MLA / SSM / hybrid / enc-dec /
modality-stub variants; families toggle features rather than subclassing so
`lm.py` can stay a single scan-over-layers implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None   # default: d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    rope_kind: str = "standard"      # standard|mrope|none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # t/h/w split of head_dim/2
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    capacity_factor: float = 1.25
    first_dense_layers: int = 0      # leading layers with a dense FFN
    dense_d_ff: int = 0              # FFN dim of those layers
    router_aux_weight: float = 0.01

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0             # 0 = full-rank Q projection
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    ssm_ngroups: int = 1

    # --- hybrid (Zamba2): shared attention block every k SSM layers ---
    attn_every: int = 0

    # --- encoder-decoder (Seamless) ---
    enc_dec: bool = False
    n_enc_layers: int = 0

    # --- modality frontend stubs ---
    frontend: Optional[str] = None   # vision|audio
    frontend_dim: int = 0            # raw embedding dim fed by the stub
    frontend_len: int = 0            # positions consumed by the stub

    # --- decode-attention dispatch ---
    # "dense": the in-model unchunked softmax path (training parity);
    # "registry": route single-token decode attention through the
    # registered flash-decode EngineOp (repro.kernels.attention), so the
    # dispatcher's §6 Advice picks the engine per layer and the serving
    # engine exercises the same kernel the paper's evidence tables gate.
    decode_attention_impl: str = "dense"
    # engine flag forwarded to the registry op ('auto' defers to the
    # advisor; 'vector'/'matrix' force a variant for A/B serving runs)
    decode_attention_engine: str = "auto"

    # --- capabilities ---
    sub_quadratic: bool = False      # may run the long_500k cell
    pad_vocab_to: int = 256          # Megatron-style table padding so the
                                     # vocab dim shards over any TP degree

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        m = self.pad_vocab_to
        return ((self.vocab + m - 1) // m) * m

    # ---- derived ----
    @property
    def q_dim(self) -> int:
        if self.use_mla:
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6*N*D)."""
        return sum(int(x) for x in _count(self).values())

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k of routed experts)."""
        c = _count(self)
        total = sum(int(v) for v in c.values())
        if self.n_experts:
            routed = c["moe_routed"]
            total -= int(routed * (1 - (self.top_k / self.n_experts)))
        return total


def _count(cfg: ModelConfig) -> dict:
    """Parameter counts by component (python ints, no arrays)."""
    d, v = cfg.d_model, cfg.vocab
    counts = {"embed": v * d, "head": 0 if cfg.tie_embeddings else v * d,
              "final_norm": d}
    L = cfg.n_layers

    def attn_params() -> int:
        if cfg.use_mla:
            q = (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.q_dim
                 if cfg.q_lora_rank else d * cfg.q_dim)
            kv_a = d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
            kv_b = cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim
                                                     + cfg.v_head_dim)
            o = cfg.n_heads * cfg.v_head_dim * d
            return q + kv_a + kv_b + o
        qkv = d * (cfg.q_dim + 2 * cfg.kv_dim)
        if cfg.qkv_bias:
            qkv += cfg.q_dim + 2 * cfg.kv_dim
        return qkv + cfg.q_dim * d

    def ffn_params(f: int) -> int:
        return 3 * d * f  # SwiGLU: gate, up, down

    def ssm_params() -> int:
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
        g = cfg.ssm_ngroups
        in_proj = d * (2 * di + 2 * g * n + h)   # z, x, B, C, dt
        conv = (di + 2 * g * n) * cfg.ssm_conv
        extra = 2 * h + di                        # A, D, norm
        out = di * d
        return in_proj + conv + extra + out

    if cfg.family == "ssm":
        counts["ssm"] = L * ssm_params()
    elif cfg.family == "hybrid":
        counts["ssm"] = L * ssm_params()
        counts["shared_attn"] = attn_params() + ffn_params(cfg.d_ff) + 2 * d
        counts["ssm_norms"] = L * d
    elif cfg.n_experts:
        moe_layers = L - cfg.first_dense_layers
        counts["attn"] = L * attn_params()
        counts["moe_routed"] = moe_layers * cfg.n_experts * 3 * d * cfg.moe_d_ff
        if cfg.n_shared_experts:
            counts["moe_shared"] = moe_layers * 3 * d * (
                cfg.n_shared_experts * cfg.moe_d_ff)
        counts["router"] = moe_layers * d * cfg.n_experts
        if cfg.first_dense_layers:
            counts["dense_ffn"] = cfg.first_dense_layers * ffn_params(
                cfg.dense_d_ff or cfg.d_ff)
        counts["norms"] = L * 2 * d
    else:
        counts["attn"] = L * attn_params()
        counts["ffn"] = L * ffn_params(cfg.d_ff)
        counts["norms"] = L * 2 * d
        if cfg.enc_dec:
            # encoder stack + cross attention in decoder
            enc = cfg.n_enc_layers * (attn_params() + ffn_params(cfg.d_ff)
                                      + 2 * d)
            cross = cfg.n_layers * (attn_params() + d)
            counts["encoder"] = enc
            counts["cross_attn"] = cross
    if cfg.frontend:
        counts["frontend_proj"] = cfg.frontend_dim * d + d
    return counts
