"""Attention: GQA with chunked (flash-style) softmax, KV caches, MLA.

Grouped-query attention never materializes repeated KV heads: scores are
computed with the (kv_head, group) factorization.  Long sequences go
through a double-scan online-softmax path (q-chunks outer, kv-chunks
inner) so the dry-run's compiled memory stays tile-sized instead of
O(S^2).

MLA (DeepSeek-V2) caches the compressed latent + shared rope key; decode
uses the *absorbed* formulation (w_uk folded into q, w_uv folded into the
output projection), which is the memory-bound GEMV shape the paper's
advisor classifies -- see DESIGN.md §5.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, apply_mrope, apply_rope, dense_init, rmsnorm

NEG_INF = -1e30


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    if cfg.use_mla and not cross:
        return _init_mla(key, cfg)
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], d, cfg.q_dim),
        "wk": dense_init(ks[1], d, cfg.kv_dim),
        "wv": dense_init(ks[2], d, cfg.kv_dim),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.head_dim, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
    return p


def _init_mla(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, r = cfg.d_model, cfg.kv_lora_rank
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    h = cfg.n_heads
    p = {
        "wkv_a": dense_init(ks[1], d, r + rope_d),
        "kv_norm": jnp.ones((r,), jnp.float32),
        "wkv_b": dense_init(ks[2], r, h * (nope + vd)),
        "wo": dense_init(ks[3], h * vd, d),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, cfg.q_lora_rank)
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), jnp.float32)
        p["wq_b"] = dense_init(ks[4], cfg.q_lora_rank, h * (nope + rope_d))
    else:
        p["wq"] = dense_init(ks[0], d, h * (nope + rope_d))
    return p


# --------------------------------------------------------------------------
# core attention math
# --------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B,Sq,KH,G,Dh), k: (B,Skv,KH,Dh) -> (B,KH,G,Sq,Skv)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k)


def _gqa_out(w, v):
    """w: (B,KH,G,Sq,Skv), v: (B,Skv,KH,Dh) -> (B,Sq,KH,G,Dh)."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", w, v)


def _sdpa_dense(q, k, v, q_pos, kv_pos, causal: bool, kv_len=None):
    """Unchunked softmax attention with GQA factorization.

    q: (B,Sq,KH,G,Dh); k,v: (B,Skv,KH,Dh); positions broadcast (B,S)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s = _gqa_scores(q, k).astype(jnp.float32) * scale
    mask = jnp.ones(s.shape[-2:], bool)
    if causal:
        mask = kv_pos[:, None, :] <= q_pos[:, :, None]      # (B,Sq,Skv)
        mask = mask[:, None, None]
    if kv_len is not None:
        valid = (jnp.arange(k.shape[1])[None, :] < kv_len[:, None])
        mask = mask & valid[:, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return _gqa_out(w, v)


def _sdpa_flash(q, k, v, q_pos, kv_pos, causal: bool,
                q_chunk: int, kv_chunk: int):
    """Double-scan online-softmax attention (compiled memory = tiles)."""
    b, sq, kh, g, dh = q.shape
    skv = k.shape[1]
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, skv)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    nq = sq // q_chunk
    nk = skv // kv_chunk
    qr = q.reshape(b, nq, q_chunk, kh, g, dh).swapaxes(0, 1)
    qp = q_pos.reshape(b, nq, q_chunk).swapaxes(0, 1)
    kr = k.reshape(b, nk, kv_chunk, kh, dh).swapaxes(0, 1)
    vr = v.reshape(b, nk, kv_chunk, kh, dh).swapaxes(0, 1)
    kp = kv_pos.reshape(b, nk, kv_chunk).swapaxes(0, 1)

    def q_block(carry, qc):
        qi, qpi = qc

        def kv_block(state, kc):
            ki, vi, kpi = kc
            acc, m, l = state
            s = _gqa_scores(qi, ki).astype(jnp.float32) * scale
            if causal:
                mask = kpi[:, None, :] <= qpi[:, :, None]
                s = jnp.where(mask[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + _gqa_out(
                p.astype(qi.dtype), vi).astype(jnp.float32).transpose(
                    0, 2, 3, 1, 4)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, kh, g, q_chunk, dh), jnp.float32)
        m0 = jnp.full((b, kh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(kv_block, (acc0, m0, l0), (kr, vr, kp))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).transpose(0, 3, 1, 2, 4)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, (qr, qp))        # (nq,B,qc,KH,G,Dh)
    return outs.swapaxes(0, 1).reshape(b, sq, kh, g, dh)


def sdpa(q, k, v, q_pos, kv_pos, *, causal: bool, kv_len=None,
         q_chunk: int = 512, kv_chunk: int = 1024):
    """Dispatch dense vs flash by size; shapes as in _sdpa_dense."""
    sq, skv = q.shape[1], k.shape[1]
    if (sq > q_chunk and sq % q_chunk == 0 and skv % kv_chunk == 0
            and kv_len is None):
        return _sdpa_flash(q, k, v, q_pos, kv_pos, causal, q_chunk, kv_chunk)
    return _sdpa_dense(q, k, v, q_pos, kv_pos, causal, kv_len)


# --------------------------------------------------------------------------
# GQA attention layer (standard path)
# --------------------------------------------------------------------------

def _project_qkv(p: Params, x, kv_x, cfg: ModelConfig):
    dtype = x.dtype
    q = x @ p["wq"].astype(dtype)
    k = kv_x @ p["wk"].astype(dtype)
    v = kv_x @ p["wv"].astype(dtype)
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    b, sq = x.shape[:2]
    skv = kv_x.shape[1]
    q = q.reshape(b, sq, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, skv, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, skv, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _rope_qk(q, k, q_pos, kv_pos, cfg: ModelConfig):
    if cfg.rope_kind == "none":
        return q, k
    if cfg.rope_kind == "mrope":
        return (apply_mrope(q, q_pos, cfg.rope_theta, cfg.mrope_sections),
                apply_mrope(k, kv_pos, cfg.rope_theta, cfg.mrope_sections))
    return (apply_rope(q, q_pos, cfg.rope_theta),
            apply_rope(k, kv_pos, cfg.rope_theta))


def _scalar_pos(positions, cfg: ModelConfig):
    """The (B,S) stream used for causal masking (mrope uses temporal)."""
    return positions[0] if cfg.rope_kind == "mrope" else positions


def attention(p: Params, x, cfg: ModelConfig, *, positions,
              cache: Optional[Dict] = None, cache_index=None,
              kv_x=None, kv_positions=None, causal: bool = True
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Attention in all modes.

    train/prefill: cache=None -> full self-attention (returns fresh cache
      when cache_index == 'prefill').
    decode: cache given + cache_index (B,) -> one-step attention against
      the cache; cache updated in place.
    cross: kv_x given -> encoder-decoder attention (no causal mask).
    """
    if cfg.use_mla and kv_x is None:
        return mla_attention(p, x, cfg, positions=positions, cache=cache,
                             cache_index=cache_index)
    b, sq, _ = x.shape
    group = cfg.n_heads // cfg.n_kv_heads

    if kv_x is not None:                                     # cross-attention
        k, v = make_cross_kv(p, kv_x, cfg)
        out = cross_attend(p, x, cfg, (k, v),
                           _scalar_pos(positions, cfg), kv_positions)
        return out, {"ck": k, "cv": v}
    elif cache is None:                                      # train / prefill
        q, k, v = _project_qkv(p, x, x, cfg)
        q, k = _rope_qk(q, k, positions, positions, cfg)
        new_cache = {"k": k, "v": v}
        q = q.reshape(b, sq, cfg.n_kv_heads, group, cfg.head_dim)
        qpos = _scalar_pos(positions, cfg)
        out = sdpa(q, k, v, qpos, qpos, causal=causal)
        out = out.reshape(b, sq, cfg.n_heads * cfg.head_dim)
        return out @ p["wo"].astype(x.dtype), new_cache
    else:                                                    # decode
        q, k, v = _project_qkv(p, x, x, cfg)
        kv_pos_new = _decode_positions(positions, cache_index, cfg)
        q, k = _rope_qk(q, k, positions, kv_pos_new, cfg)
        if cache["k"].dtype == jnp.int8:
            # quantized KV cache: per-(position, head) scales (beyond-paper
            # memory-term optimization; see EXPERIMENTS.md §Perf)
            cache = _int8_cache_update(cache, k, v, cache_index)
            ck = (cache["k"].astype(x.dtype)
                  * cache["k_scale"][..., None].astype(x.dtype))
            cv = (cache["v"].astype(x.dtype)
                  * cache["v_scale"][..., None].astype(x.dtype))
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
            cache = {**cache, "k": ck, "v": cv}
            ck = ck.astype(x.dtype)
            cv = cv.astype(x.dtype)
        q = q.reshape(b, sq, cfg.n_kv_heads, group, cfg.head_dim)
        if cfg.decode_attention_impl == "registry" and sq == 1:
            # single-token decode through the registered flash-decode
            # EngineOp: the dispatcher's memoized §6 Advice routes the
            # per-layer cache scan (engine='auto' -> vector on this
            # memory-bound shape), identical numerics to the dense path
            from ..kernels.attention.ops import decode_attention
            out = decode_attention(q[:, 0], ck, cv, cache_index + sq,
                                   engine=cfg.decode_attention_engine)
            out = out[:, None]
        else:
            kv_len = jnp.full((b,), cache_index + sq, jnp.int32)
            kv_pos = jnp.broadcast_to(jnp.arange(ck.shape[1])[None],
                                      (b, ck.shape[1]))
            qpos = _scalar_pos(positions, cfg)
            out = _sdpa_dense(q, ck, cv, qpos, kv_pos, causal=True,
                              kv_len=kv_len)
    out = out.reshape(b, sq, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"].astype(x.dtype), cache


def make_cross_kv(p: Params, enc_out, cfg: ModelConfig):
    """Project encoder output to K/V once (cached across decode steps)."""
    dtype = enc_out.dtype
    b, se, _ = enc_out.shape
    k = enc_out @ p["wk"].astype(dtype)
    v = enc_out @ p["wv"].astype(dtype)
    if "bk" in p:
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return (k.reshape(b, se, cfg.n_kv_heads, cfg.head_dim),
            v.reshape(b, se, cfg.n_kv_heads, cfg.head_dim))


def cross_attend(p: Params, x, cfg: ModelConfig, kv, q_pos, kv_pos):
    dtype = x.dtype
    b, sq, _ = x.shape
    group = cfg.n_heads // cfg.n_kv_heads
    q = x @ p["wq"].astype(dtype)
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
    q = q.reshape(b, sq, cfg.n_kv_heads, group, cfg.head_dim)
    k, v = kv
    out = _sdpa_dense(q, k.astype(dtype), v.astype(dtype), q_pos, kv_pos,
                      causal=False)
    out = out.reshape(b, sq, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"].astype(dtype)


def _decode_positions(positions, cache_index, cfg: ModelConfig):
    return positions


def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
               ) -> Dict:
    if cfg.use_mla:
        lat_dtype = jnp.bfloat16 if dtype == jnp.int8 else dtype
        return {
            "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank),
                                lat_dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), lat_dtype),
        }
    c = {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
    if dtype == jnp.int8:
        c["k_scale"] = jnp.zeros((batch, max_len, cfg.n_kv_heads),
                                 jnp.float32)
        c["v_scale"] = jnp.zeros((batch, max_len, cfg.n_kv_heads),
                                 jnp.float32)
    return c


def _int8_cache_update(cache: Dict, k, v, cache_index) -> Dict:
    """Quantize new K/V rows with per-(position, head) scales."""
    def q(x):
        scale = jnp.max(jnp.abs(x), axis=-1) / 127.0          # (B,S,KH)
        scale = jnp.maximum(scale, 1e-8)
        xq = jnp.clip(jnp.round(x / scale[..., None]), -127, 127)
        return xq.astype(jnp.int8), scale.astype(jnp.float32)

    kq, ks = q(k.astype(jnp.float32))
    vq, vs = q(v.astype(jnp.float32))
    upd = lambda buf, val: jax.lax.dynamic_update_slice_in_dim(
        buf, val.astype(buf.dtype), cache_index, axis=1)
    return {"k": upd(cache["k"], kq), "v": upd(cache["v"], vq),
            "k_scale": upd(cache["k_scale"], ks),
            "v_scale": upd(cache["v_scale"], vs)}


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# --------------------------------------------------------------------------

def _mla_q(p: Params, x, cfg: ModelConfig):
    dtype = x.dtype
    if cfg.q_lora_rank:
        ql = rmsnorm(p["q_norm"], x @ p["wq_a"].astype(dtype), cfg.norm_eps)
        q = ql @ p["wq_b"].astype(dtype)
    else:
        q = x @ p["wq"].astype(dtype)
    b, s = x.shape[:2]
    q = q.reshape(b, s, cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim)
    return q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]


def mla_attention(p: Params, x, cfg: ModelConfig, *, positions,
                  cache=None, cache_index=None):
    """MLA: latent-compressed KV.  Prefill caches (latent, k_rope); decode
    runs the absorbed formulation entirely in latent space."""
    dtype = x.dtype
    b, s, _ = x.shape
    h, r = cfg.n_heads, cfg.kv_lora_rank
    nope, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    kv_a = x @ p["wkv_a"].astype(dtype)                     # (B,S,r+rd)
    latent = rmsnorm(p["kv_norm"], kv_a[..., :r], cfg.norm_eps)
    k_rope_raw = kv_a[..., r:].reshape(b, s, 1, rd)

    q_nope, q_rope = _mla_q(p, x, cfg)
    scale = 1.0 / jnp.sqrt(jnp.asarray(nope + rd, jnp.float32))

    if cache is None:                                        # train / prefill
        k_rope = apply_rope(k_rope_raw, positions, cfg.rope_theta)
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        kv = latent @ p["wkv_b"].astype(dtype)               # (B,S,H*(nope+vd))
        kv = kv.reshape(b, s, h, nope + vd)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        sc = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkod->bhqk", q_rope, k_rope)
              ).astype(jnp.float32) * scale
        mask = positions[:, None, :] <= positions[:, :, None]
        sc = jnp.where(mask[:, None], sc, NEG_INF)
        w = jax.nn.softmax(sc, axis=-1).astype(dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
        new_cache = {"latent": latent, "k_rope": k_rope.squeeze(2)}
        out = out.reshape(b, s, h * vd)
        return out @ p["wo"].astype(dtype), new_cache

    # ---- decode: absorbed path ----
    kv_pos = positions
    k_rope = apply_rope(k_rope_raw, kv_pos, cfg.rope_theta)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    lat = jax.lax.dynamic_update_slice_in_dim(
        cache["latent"], latent.astype(cache["latent"].dtype), cache_index,
        axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope.squeeze(2).astype(cache["k_rope"].dtype),
        cache_index, axis=1)
    cache = {"latent": lat, "k_rope": kr}
    wkv_b = p["wkv_b"].astype(dtype).reshape(r, h, nope + vd)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
    # absorb: q' = q_nope @ w_uk  -> score against the latent directly
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)      # (B,1,H,r)
    latf = lat.astype(dtype)
    sc = (jnp.einsum("bqhr,bkr->bhqk", q_lat, latf)
          + jnp.einsum("bqhd,bkd->bhqk", q_rope, kr.astype(dtype))
          ).astype(jnp.float32) * scale
    kv_len = cache_index + s
    valid = jnp.arange(lat.shape[1])[None, :] < kv_len
    sc = jnp.where(valid[:, None, None], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1).astype(dtype)
    out_lat = jnp.einsum("bhqk,bkr->bqhr", w, latf)          # (B,1,H,r)
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_uv)        # (B,1,H,vd)
    out = out.reshape(b, s, h * vd)
    return out @ p["wo"].astype(dtype), cache
