"""Mamba2 (SSD, state-space duality) layer: chunked train, recurrent decode.

The chunked SSD algorithm (Dao & Gu 2024, §6) splits the sequence into
chunks; intra-chunk terms are dense matmuls (MXU food) and inter-chunk
terms are a short scan over per-chunk states.  This is the paper's
Eq.-13 'temporal blocking escape hatch' realized in an LM: chunking
*raises* operational intensity, which is why the matrix engine is the
right tool here and not for SCALE/SpMV (DESIGN.md §5).

Decode keeps the recurrent state (B, H, P, N) plus a small causal-conv
tail; one token costs O(d_inner * N) -- firmly memory-bound.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, dense_init, rmsnorm


def init_ssm(key, cfg: ModelConfig) -> Params:
    """Projections kept separate (z/x/BC/dt) so tensor parallelism can
    shard the d_inner/head dims without slicing a packed axis."""
    ks = jax.random.split(key, 6)
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, g = cfg.ssm_nheads, cfg.ssm_ngroups
    return {
        "w_z": dense_init(ks[0], d, di),
        "w_x": dense_init(ks[1], d, di),
        "w_bc": dense_init(ks[4], d, 2 * g * n),
        "w_dt": dense_init(ks[5], d, h),
        "conv_x": jax.random.normal(ks[1], (cfg.ssm_conv, di),
                                    jnp.float32) * 0.1,
        "conv_x_b": jnp.zeros((di,), jnp.float32),
        "conv_bc": jax.random.normal(ks[1], (cfg.ssm_conv, 2 * g * n),
                                     jnp.float32) * 0.1,
        "conv_bc_b": jnp.zeros((2 * g * n,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (h,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[3], di, d),
    }


def _split_proj(p: Params, u: jnp.ndarray, cfg: ModelConfig):
    dtype = u.dtype
    z = u @ p["w_z"].astype(dtype)
    x = u @ p["w_x"].astype(dtype)
    bc = u @ p["w_bc"].astype(dtype)
    dt = u @ p["w_dt"].astype(dtype)
    return z, x, bc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, width K.  conv_state: (B, K-1, C) tail."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i].astype(xbc.dtype)
              for i in range(k))
    new_state = xp[:, -(k - 1):]
    return jax.nn.silu(out + conv_b.astype(xbc.dtype)), new_state


def _ssd_chunked(x, dt, a, b, c, chunk: int):
    """Chunked SSD scan.

    x: (B,S,H,P), dt: (B,S,H), a: (H,) (positive decay rate),
    b,c: (B,S,G,N).  Returns y: (B,S,H,P), final_state: (B,H,P,N).
    """
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    def r(t, extra=()):  # reshape into chunks
        return t.reshape(bs, nc, chunk, *t.shape[2:])

    xc, dtc, bc_, cc = r(x), r(dt), r(b), r(c)
    da = dtc * a[None, None, None, :]                       # (B,nc,Q,H)
    cum = jnp.cumsum(da, axis=2)                            # within-chunk
    total = cum[:, :, -1]                                   # (B,nc,H)

    # intra-chunk (diagonal block): L[q,t] = exp(cum[q]-cum[t]) for q>=t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,Q,Q,H)
    qi = jnp.arange(chunk)
    causal = (qi[:, None] >= qi[None, :])[None, None, :, :, None]
    l_mat = jnp.where(causal, jnp.exp(-seg), 0.0)           # decay q<-t
    cb = jnp.einsum("bzqgn,bztgn->bzqtg", cc, bc_)          # (B,nc,Q,Q,G)
    cb = jnp.repeat(cb, rep, axis=-1)                       # (B,nc,Q,Q,H)
    att = cb * l_mat * dtc[:, :, None, :, :]                # weight dt at t
    y_diag = jnp.einsum("bzqth,bzthp->bzqhp", att, xc)

    # per-chunk input states: sum_t exp(-(total - cum[t])) dt_t b_t x_t
    decay_in = jnp.exp(cum - total[:, :, None])             # (B,nc,Q,H)
    bx = jnp.einsum("bztgn,bzthp,bzth->bzhpn",
                    bc_, xc, dtc * decay_in)                # uses group bcast
    # NOTE: einsum above broadcasts g->h only when g==1; general case:
    if g != 1:
        bfull = jnp.repeat(bc_, rep, axis=3)
        bx = jnp.einsum("bzthn,bzthp,bzth->bzhpn", bfull, xc, dtc * decay_in)

    # inter-chunk recurrence over states
    def step(state, inp):
        bx_z, tot_z = inp                                    # (B,H,P,N),(B,H)
        new = state * jnp.exp(-tot_z)[..., None, None] + bx_z
        return new, state                                    # emit state *before* this chunk

    init = jnp.zeros((bs, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        step, init, (bx.swapaxes(0, 1), total.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                 # (B,nc,H,P,N)

    # inter-chunk output: y_off[q] = c_q . (decay to q) state_prev
    decay_out = jnp.exp(-cum)                                # (B,nc,Q,H)
    cfull = jnp.repeat(cc, rep, axis=3) if g != 1 else cc
    if g == 1:
        y_off = jnp.einsum("bzqgn,bzhpn,bzqh->bzqhp",
                           cc, prev_states, decay_out)
    else:
        y_off = jnp.einsum("bzqhn,bzhpn,bzqh->bzqhp",
                           cfull, prev_states, decay_out)
    y = (y_diag + y_off).reshape(bs, s, h, p)
    return y, final


def ssm_layer(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
              state: Optional[Dict] = None
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Mamba2 block.  state=None -> chunked scan over the full sequence;
    state given -> single-token recurrent update (decode)."""
    dtype = x.dtype
    di, n, h, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_ngroups
    ph = cfg.ssm_headdim
    bsz, s, _ = x.shape

    z, xr, bcr, dt = _split_proj(p, x, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])         # (B,S,H)
    a = jnp.exp(p["a_log"])                                   # (H,) > 0

    cx = state["conv_x"] if state is not None else None
    cbc = state["conv_bc"] if state is not None else None
    x_c, tail_x = _causal_conv(xr, p["conv_x"], p["conv_x_b"], cx)
    bc_c, tail_bc = _causal_conv(bcr, p["conv_bc"], p["conv_bc_b"], cbc)
    xin = x_c.reshape(bsz, s, h, ph)
    bmat = bc_c[..., :g * n].reshape(bsz, s, g, n)
    cmat = bc_c[..., g * n:].reshape(bsz, s, g, n)

    if state is None:
        y, final = _ssd_chunked(xin.astype(jnp.float32), dt, a,
                                bmat.astype(jnp.float32),
                                cmat.astype(jnp.float32),
                                min(cfg.ssm_chunk, s))
        new_state = {"ssm": final.astype(jnp.float32),
                     "conv_x": tail_x.astype(jnp.float32),
                     "conv_bc": tail_bc.astype(jnp.float32)}
    else:
        # recurrent: state' = state * exp(-dt a) + dt * b x^T ; y = c . state'
        st = state["ssm"]                                     # (B,H,P,N)
        dt1 = dt[:, 0]                                        # (B,H)
        decay = jnp.exp(-dt1 * a[None])[..., None, None]      # (B,H,1,1)
        bx = jnp.einsum("bgn,bhp,bh->bhpn",
                        bmat[:, 0].astype(jnp.float32),
                        xin[:, 0].astype(jnp.float32), dt1)
        st = st * decay + bx
        y = jnp.einsum("bgn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), st)
        y = y[:, None]                                        # (B,1,H,P)
        new_state = {"ssm": st, "conv_x": tail_x.astype(jnp.float32),
                     "conv_bc": tail_bc.astype(jnp.float32)}

    y = y + xin.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"].astype(dtype), new_state


def make_ssm_state(cfg: ModelConfig, batch: int) -> Dict:
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim,
                          cfg.ssm_state), jnp.float32),
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                            jnp.float32),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1,
                              2 * cfg.ssm_ngroups * cfg.ssm_state),
                             jnp.float32),
    }
