"""Per-op Eq. 2 traits for one LM decode step → the model-scale verdict.

The paper's Eq. 23/24 ceiling was verified on isolated kernels; this
module asks what fraction of a *whole decode step* that verdict governs.
Every layer op of a config's decode step (qkv/o projections, the
flash-decode attention cache scan, MLP or MoE gate+experts, the SSM
mixer, norms, embedding and LM head) gets its own
:class:`~repro.core.intensity.KernelTraits` (W flops, Q bytes for one
batched single-token step), the dispatcher's memoized §6 Advice
classifies each as memory- vs compute-bound (Eq. 4), and
:func:`model_verdict` folds the per-op roofline times
(max(Q/B_mem, W/P_engine)) into time/byte fractions — the numbers the
schema-4 lm serving records carry and the ``model_verdict`` claim
re-derives.

Weight-stationary matmuls all share one shape of traits (W = 2·B·params,
Q = params·E for E-byte weights), so the per-op parameter splits reuse
the same component formulas as ``ModelConfig.param_count`` — the verdict
can never disagree with the config's own accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..core.dispatch import DEFAULT_DISPATCHER, Dispatcher
from ..core.hw import HardwareSpec
from ..core.intensity import KernelTraits
from .config import ModelConfig

__all__ = ["ModelVerdict", "OpVerdict", "decode_op_traits",
           "model_verdict", "step_traits", "verdict_payload"]


# --------------------------------------------------------------------------
# per-op parameter splits (mirrors config._count's component formulas)
# --------------------------------------------------------------------------

def _qkv_params(cfg: ModelConfig) -> int:
    """Input-side attention projections (q, k, v; MLA: q/kv down+up)."""
    d = cfg.d_model
    if cfg.use_mla:
        q = (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.q_dim
             if cfg.q_lora_rank else d * cfg.q_dim)
        kv_a = d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
        kv_b = cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim
                                                 + cfg.v_head_dim)
        return q + kv_a + kv_b
    qkv = d * (cfg.q_dim + 2 * cfg.kv_dim)
    if cfg.qkv_bias:
        qkv += cfg.q_dim + 2 * cfg.kv_dim
    return qkv


def _o_params(cfg: ModelConfig) -> int:
    """Output attention projection."""
    if cfg.use_mla:
        return cfg.n_heads * cfg.v_head_dim * cfg.d_model
    return cfg.q_dim * cfg.d_model


def _ffn_params(cfg: ModelConfig, f: int) -> int:
    return 3 * cfg.d_model * f  # SwiGLU: gate, up, down


def _ssm_params(cfg: ModelConfig) -> int:
    d, di = cfg.d_model, cfg.d_inner
    n, h, g = cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_ngroups
    in_proj = d * (2 * di + 2 * g * n + h)
    conv = (di + 2 * g * n) * cfg.ssm_conv
    extra = 2 * h + di
    return in_proj + conv + extra + di * d


def _attn_layers(cfg: ModelConfig) -> int:
    """Attention-block applications per decode step."""
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every  # shared block, reapplied
    return cfg.n_layers


# --------------------------------------------------------------------------
# the op → traits map
# --------------------------------------------------------------------------

def _matmul(name: str, batch: int, params: int, e: int,
            act_elems: int = 0) -> KernelTraits:
    """Weight-stationary matmul traits for one batched decode token.

    W = 2·B·params (one multiply-add per weight per token); Q streams
    the weights once plus the activations in/out (E bytes each).
    """
    return KernelTraits(name, 2.0 * batch * params,
                        float(params * e + batch * act_elems * e))


def decode_op_traits(cfg: ModelConfig, batch: int, cache_len: int, *,
                     dtype_bytes: int = 2,
                     cache_bytes: Optional[int] = None,
                     ) -> Dict[str, KernelTraits]:
    """Eq. 2 traits per layer op, aggregated over one decode step.

    One batched single-token step against a ``cache_len`` KV/SSM state,
    weights and activations in ``dtype_bytes``-byte precision (KV cache
    in ``cache_bytes``, default the same).  Keys are stable op names in
    execution order; values aggregate every layer's instance of that op
    (the scan reuses one block, the bytes do not).
    """
    e = int(dtype_bytes)
    ec = int(cache_bytes) if cache_bytes is not None else e
    b, s = int(batch), int(cache_len)
    d = cfg.d_model
    la = _attn_layers(cfg)
    ops: Dict[str, KernelTraits] = {}

    # one embedding row gathered per token: pure traffic, no flops
    ops["embed"] = KernelTraits("embed", 0.0, float(b * d * e))

    if la:
        ops["qkv_proj"] = _matmul("qkv_proj", b, la * _qkv_params(cfg), e,
                                  act_elems=la * (d + cfg.q_dim
                                                  + 2 * cfg.kv_dim))
        if cfg.use_mla:
            # absorbed decode scans the latent cache: score + output
            # contractions over (kv_lora_rank + qk_rope_dim) per head
            r = cfg.kv_lora_rank + cfg.qk_rope_dim
            attn = KernelTraits("attention",
                                4.0 * b * cfg.n_heads * s * r * la,
                                float(b * s * r * ec * la))
        else:
            # the registered flash-decode op's own traits formula
            # (repro.kernels.attention.ops._traits), summed over layers
            kh, g, dh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, \
                cfg.head_dim
            attn = KernelTraits("attention",
                                4.0 * b * kh * g * s * dh * la,
                                2.0 * b * s * kh * dh * ec * la)
        ops["attention"] = attn
        ops["o_proj"] = _matmul("o_proj", b, la * _o_params(cfg), e,
                                act_elems=la * 2 * d)

    if cfg.family in ("ssm", "hybrid"):
        # SSM mixer: projections are weight-stationary; the recurrent
        # state (h, conv windows) is read+written once per step
        state = (cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state
                 + (cfg.ssm_conv - 1) * (cfg.d_inner
                                         + 2 * cfg.ssm_ngroups
                                         * cfg.ssm_state))
        params = cfg.n_layers * _ssm_params(cfg)
        ops["ssm_mixer"] = KernelTraits(
            "ssm_mixer",
            2.0 * b * params + 6.0 * b * cfg.n_layers * cfg.d_inner
            * cfg.ssm_state,
            float(params * e + 2 * b * cfg.n_layers * state * 4))

    if cfg.family == "hybrid":
        ops["mlp"] = _matmul("mlp", b, la * _ffn_params(cfg, cfg.d_ff), e,
                             act_elems=la * 2 * d)
    elif cfg.n_experts:
        moe_layers = cfg.n_layers - cfg.first_dense_layers
        ops["moe_gate"] = _matmul("moe_gate", b,
                                  moe_layers * d * cfg.n_experts, e)
        expert = 3 * d * cfg.moe_d_ff
        active = cfg.top_k + cfg.n_shared_experts        # per token
        touched = min(b * cfg.top_k, cfg.n_experts) + cfg.n_shared_experts
        ops["moe_experts"] = KernelTraits(
            "moe_experts", 2.0 * b * moe_layers * active * expert,
            float(moe_layers * touched * expert * e + b * moe_layers
                  * 2 * d * e))
        if cfg.first_dense_layers:
            f = cfg.dense_d_ff or cfg.d_ff
            ops["mlp"] = _matmul(
                "mlp", b, cfg.first_dense_layers * _ffn_params(cfg, f), e,
                act_elems=cfg.first_dense_layers * 2 * d)
    elif cfg.family not in ("ssm",):
        ops["mlp"] = _matmul("mlp", b,
                             cfg.n_layers * _ffn_params(cfg, cfg.d_ff), e,
                             act_elems=cfg.n_layers * 2 * d)

    if cfg.enc_dec:
        # decoder cross-attention against the cached encoder K/V (the
        # encoder itself runs at prefill, not in the decode step)
        cross = cfg.n_layers * (_qkv_params(cfg) + _o_params(cfg))
        kv = b * s * cfg.kv_dim * ec * cfg.n_layers
        ops["cross_attn"] = KernelTraits(
            "cross_attn",
            2.0 * b * cross + 4.0 * b * cfg.n_heads * cfg.head_dim * s
            * cfg.n_layers,
            float(cross * e + 2 * kv))

    # rmsnorm applications: ~5 flops/element, read+write the residual
    n_norms = 1 + (2 * la if cfg.family != "hybrid" else 2 * la
                   + cfg.n_layers)
    if cfg.family == "ssm":
        n_norms = 1 + cfg.n_layers
    ops["norms"] = KernelTraits("norms", 5.0 * b * d * n_norms,
                                float((2 * b * d + d) * n_norms * e))

    # tied or not, decode reads the full (padded) vocab projection
    ops["head"] = _matmul("head", b, cfg.vocab_padded * d, e,
                          act_elems=d + cfg.vocab_padded)
    return ops


def step_traits(cfg: ModelConfig, batch: int, cache_len: int, *,
                dtype_bytes: int = 2,
                cache_bytes: Optional[int] = None) -> KernelTraits:
    """Whole-decode-step Eq. 2 traits: the per-op map, summed.

    What the serving executor's Advice (and therefore every schema-4 lm
    record's intensity/boundedness join fields) is derived from — by
    construction consistent with the per-op verdict it rides next to.
    """
    ops = decode_op_traits(cfg, batch, cache_len, dtype_bytes=dtype_bytes,
                           cache_bytes=cache_bytes)
    return KernelTraits("decode_step",
                        sum(t.work_flops for t in ops.values()),
                        sum(t.traffic_bytes for t in ops.values()))


# --------------------------------------------------------------------------
# the verdict
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpVerdict:
    """One decode-step op, classified and placed on the roofline."""

    name: str
    flops: float
    bytes: float
    intensity: float        # Eq. 2: I = W / Q
    memory_bound: bool      # Eq. 4: I < B_vector
    engine: str             # §6 Advice route ('vector'|'matrix')
    mxu_ceiling: float      # Eq. 17/23/24 matrix-engine ceiling
    time_s: float           # roofline time: max(Q/B_mem, W/P_engine)
    time_frac: float        # share of the modeled step time
    bytes_frac: float       # share of the step's bytes


@dataclasses.dataclass(frozen=True)
class ModelVerdict:
    """The paper's verdict at model scale, for one (config, B, S)."""

    model: str
    batch: int
    cache_len: int
    dtype_bytes: int
    ops: Tuple[OpVerdict, ...]
    step_time_s: float              # modeled: sum of per-op times
    memory_bound_time_frac: float   # step-time share under Eq. 23/24
    memory_bound_bytes_frac: float  # byte share moved by bound ops


def model_verdict(cfg: ModelConfig, batch: int, cache_len: int, *,
                  dtype_bytes: int = 2,
                  cache_bytes: Optional[int] = None,
                  dispatcher: Optional[Dispatcher] = None) -> ModelVerdict:
    """Classify every decode-step op and fold into the model verdict.

    Each op's traits go through the dispatcher's memoized §6 Advice
    (Eq. 4 boundedness, Eq. 17/23/24 ceiling, engine route); its
    roofline time is max(Q/B_mem, W/P) on the advisor's hardware model
    with P the routed engine's peak.  The returned fractions are what
    REPORT.md's "Verdict at model scale" table shows: how much of a
    decode step the paper's memory-bound ceiling governs.
    """
    disp = dispatcher if dispatcher is not None else DEFAULT_DISPATCHER
    hw: HardwareSpec = disp.hw
    traits = decode_op_traits(cfg, batch, cache_len,
                              dtype_bytes=dtype_bytes,
                              cache_bytes=cache_bytes)
    rows: List[Tuple[str, KernelTraits, object, float]] = []
    for name, t in traits.items():
        advice = disp.advise_traits(
            dataclasses.replace(t, name=f"{cfg.name}:{name}"))
        peak = hw.engine(advice.engine).peak_flops
        time_s = max(t.traffic_bytes / hw.mem_bw, t.work_flops / peak)
        rows.append((name, t, advice, time_s))
    total_t = sum(r[3] for r in rows) or 1.0
    total_q = sum(r[1].traffic_bytes for r in rows) or 1.0
    ops = tuple(
        OpVerdict(name=name, flops=t.work_flops, bytes=t.traffic_bytes,
                  intensity=t.intensity, memory_bound=advice.memory_bound,
                  engine=advice.engine,
                  mxu_ceiling=advice.max_speedup_matrix, time_s=time_s,
                  time_frac=time_s / total_t,
                  bytes_frac=t.traffic_bytes / total_q)
        for name, t, advice, time_s in rows)
    return ModelVerdict(
        model=cfg.name, batch=int(batch), cache_len=int(cache_len),
        dtype_bytes=int(dtype_bytes), ops=ops, step_time_s=total_t,
        memory_bound_time_frac=sum(o.time_frac for o in ops
                                   if o.memory_bound),
        memory_bound_bytes_frac=sum(o.bytes_frac for o in ops
                                    if o.memory_bound))


def verdict_payload(v: ModelVerdict, step_time_ms: float) -> Dict:
    """Shape a verdict + the *measured* mean decode-step wall time into
    the JSON block schema-4 lm records carry (``record["verdict"]``).

    Per-op ``time_ms`` distributes the measured step time by the
    modeled fractions, so the ``model_verdict`` claim can check the
    classification sums back to the measurement within tolerance.
    """
    return {
        "batch": v.batch,
        "cache_len": v.cache_len,
        "dtype_bytes": v.dtype_bytes,
        "step_time_ms": round(float(step_time_ms), 6),
        "memory_bound_time_frac": round(v.memory_bound_time_frac, 6),
        "memory_bound_bytes_frac": round(v.memory_bound_bytes_frac, 6),
        "ops": [{
            "name": o.name,
            "flops": o.flops,
            "bytes": o.bytes,
            "intensity": o.intensity,
            "memory_bound": bool(o.memory_bound),
            "engine": o.engine,
            "mxu_ceiling": o.mxu_ceiling,
            "time_frac": round(o.time_frac, 6),
            "time_ms": round(o.time_frac * float(step_time_ms), 6),
            "bytes_frac": round(o.bytes_frac, 6),
        } for o in v.ops],
    }
