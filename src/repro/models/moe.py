"""Mixture-of-Experts FFN: top-k router + capacity-based einsum dispatch.

The dispatch/combine tensors follow the GShard/Switch formulation, which
maps onto TPUs as two einsums around the expert GEMMs -- the expert
dimension shards over the `model` mesh axis (expert parallelism).  Router
z-loss and load-balancing aux loss are returned for the training loop.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, dense_init


def init_moe(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    p = {
        "router": dense_init(ks[0], d, e, scale=0.02),
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) / d**0.5,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) / d**0.5,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) / f**0.5,
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * cfg.moe_d_ff
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": dense_init(kk[0], d, fs),
                       "w_up": dense_init(kk[1], d, fs),
                       "w_down": dense_init(kk[2], fs, d)}
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cap, 4)


def moe_ffn(p: Params, x: jnp.ndarray, cfg: ModelConfig,
            group_size: int = 2048
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B,S,D) -> (B,S,D), aux metrics {aux_loss, z_loss}.

    GShard-style *grouped* dispatch: tokens are routed within groups of
    ``group_size`` so the (G, Sg, E, C) dispatch tensors stay tile-sized
    regardless of the global batch (capacity is per-group).  Groups align
    with the batch/data sharding, so dispatch einsums stay local and only
    the expert GEMMs touch the EP axis.
    """
    dtype = x.dtype
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    sg = min(group_size, t)
    while t % sg:                         # fall back to a divisor
        sg //= 2
    g = t // sg
    cap = _capacity(sg, cfg)
    xt = x.reshape(g, sg, d)

    logits = (xt @ p["router"].astype(dtype)).astype(jnp.float32)  # (G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                        # (G,Sg,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) in its expert's per-group buffer
    onehot_i = jax.nn.one_hot(idx, e, dtype=jnp.int32)              # (G,Sg,k,E)
    slot_flat = onehot_i.reshape(g, sg * k, e)
    pos_flat = jnp.cumsum(slot_flat, axis=1) - 1                    # (G,Sg*k,E)
    pos = (pos_flat * slot_flat).sum(-1).reshape(g, sg, k)
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(jnp.float32)

    # combine: (G,Sg,E,C) via one-hot algebra (out-of-capacity clipped out)
    exp_oh = jax.nn.one_hot(idx, e, dtype=jnp.float32)              # (G,Sg,k,E)
    cap_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                            dtype=jnp.float32)                      # (G,Sg,k,C)
    combine = jnp.einsum("gske,gskc,gsk->gsec", exp_oh, cap_oh, gate_vals)
    dispatch = (combine > 0).astype(dtype)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xt)                 # (G,E,C,D)
    gt = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dtype))
    h = jax.nn.silu(gt) * u
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dtype))
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(dtype), y)
    xt = xt.reshape(t, d)
    out = out.reshape(t, d)

    if "shared" in p:
        sp = p["shared"]
        sg = xt @ sp["w_gate"].astype(dtype)
        su = xt @ sp["w_up"].astype(dtype)
        out = out + (jax.nn.silu(sg) * su) @ sp["w_down"].astype(dtype)

    # aux losses (Switch-style load balance + router z-loss)
    me = probs.mean(axis=(0, 1))                                     # (E,)
    ce = exp_oh.sum(axis=2).mean(axis=(0, 1))                        # (E,)
    aux = (me * ce).sum() * e * cfg.router_aux_weight
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * 1e-3
    return out.reshape(b, s, d), {"aux_loss": aux, "z_loss": z}
