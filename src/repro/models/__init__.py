"""Model layer: real LM architectures over the kernel/dispatch stack.

* :mod:`repro.models.config` — one frozen :class:`ModelConfig` schema
  covering dense / MoE / MLA / SSM / hybrid / enc-dec families.
* :mod:`repro.models.lm` — the scan-over-layers forward/prefill/decode
  implementation every family shares.
* :mod:`repro.models.engine` — the :class:`DecodeEngine` serving
  entry point: jitted prefill + greedy decode with registry-dispatched
  flash-decode attention and a measured prefill/decode phase split.
* :mod:`repro.models.advisor_map` — per-op Eq. 2 traits for one decode
  step and the model-scale verdict (what fraction of a step the
  Eq. 23/24 memory-bound ceiling governs).
"""
from .advisor_map import (ModelVerdict, OpVerdict, decode_op_traits,
                          model_verdict, step_traits, verdict_payload)
from .config import ModelConfig
from .engine import DecodeEngine, GenerationResult

__all__ = [
    "DecodeEngine", "GenerationResult", "ModelConfig", "ModelVerdict",
    "OpVerdict", "decode_op_traits", "model_verdict", "step_traits",
    "verdict_payload",
]
