"""Decode engine: scan-over-layers prefill/decode with per-phase timing.

The executable half of the model-scale verdict
(``repro.models.advisor_map``): one :class:`DecodeEngine` owns a
config's parameters and the two jitted entry points — ``prefill`` (full
prompt pass, caches built once) and ``decode_step`` (one token against
the KV/SSM caches through ``repro.models.lm``'s single ``lax.scan`` over
the stacked layer block).  Attention inside the scan is
registry-dispatched by default (``decode_attention_impl='registry'``):
every layer's cache scan goes through the registered flash-decode
``EngineOp``, so the §6 Advice that classifies the decode step is
exercised by the very kernel that serves it, and the engine
('vector'|'matrix'|'auto') is a constructor flag — the serving sweep's
A/B lever.

``generate`` runs greedy decode and reports the prefill/decode wall
split plus the per-step mean the ``model_verdict`` claim anchors to;
``cache_state``/``load_cache_state`` expose the KV caches as a plain
pytree for ``repro.runtime.checkpoint`` round-trips.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..data.synthetic import make_batch
from . import lm
from .advisor_map import ModelVerdict, model_verdict, step_traits
from .config import ModelConfig

__all__ = ["DecodeEngine", "GenerationResult"]


@dataclasses.dataclass(frozen=True)
class GenerationResult:
    """One greedy generation: tokens + the phase-split timings."""

    tokens: jnp.ndarray        # (B, gen) greedy tokens (incl. first)
    logits: jnp.ndarray        # (B, vocab_padded) last-step logits
    caches: Any                # final KV/SSM caches (checkpointable)
    prefill_s: float           # prompt-pass wall time
    decode_s: float            # all decode steps' wall time
    decode_steps: int          # steps timed inside decode_s

    @property
    def per_step_s(self) -> float:
        """Mean decode-step wall time (0 for single-token generations)."""
        if self.decode_steps == 0:
            return 0.0
        return self.decode_s / self.decode_steps


class DecodeEngine:
    """Prefill + scan-over-layers greedy decode for one ModelConfig.

    The layer stack is *scanned*, not unrolled (``lm.decode_step``'s
    single ``lax.scan`` over the stacked parameter pytree), so compiled
    size is O(1) in depth; ``unroll=True`` flips to the unrolled
    reference graph the correctness tier diffs against.
    """

    def __init__(self, cfg: ModelConfig, *, max_batch: int = 4,
                 prompt_len: int = 16, max_gen: int = 16,
                 dtype=jnp.float32, seed: int = 0, engine: str = "auto",
                 attention_impl: str = "registry", unroll: bool = False,
                 params: Optional[Any] = None):
        self.cfg = dataclasses.replace(
            cfg, decode_attention_impl=attention_impl,
            decode_attention_engine=engine)
        self.engine = engine
        self.max_batch = max_batch
        self.prompt_len = prompt_len
        self.max_gen = max_gen
        self.dtype = dtype
        self.params = (params if params is not None
                       else lm.init_params(self.cfg, jax.random.key(seed)))
        cfg_ = self.cfg
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(p, cfg_, b, dtype=dtype, unroll=unroll))
        self._step = jax.jit(
            lambda p, t, c, i: lm.decode_step(p, cfg_, t, c, i,
                                              dtype=dtype, unroll=unroll))

    # -- core phases -------------------------------------------------------

    @property
    def max_len(self) -> int:
        """The serving cache length every decode step attends over."""
        return self.prompt_len + self.max_gen

    def make_prompt_batch(self, batch: Optional[int] = None,
                          seed: int = 0) -> Dict:
        """A capacity-sized synthetic prompt batch (compiled-shape reuse)."""
        return make_batch(self.cfg, batch or self.max_batch,
                          self.prompt_len, seed=seed)

    def prefill(self, batch: Dict) -> Tuple[jnp.ndarray, Any]:
        """Prompt pass: last-position logits + caches padded to max_len."""
        logits, caches = self._prefill(self.params, batch)
        return logits, lm.pad_caches(caches, self.max_len)

    def decode_step(self, tokens, caches, index: int
                    ) -> Tuple[jnp.ndarray, Any]:
        """One token for every sequence: (B,1) tokens → (B,1,V) logits."""
        return self._step(self.params, tokens, caches, jnp.int32(index))

    # -- greedy generation -------------------------------------------------

    def generate(self, batch: Dict, gen: Optional[int] = None,
                 ) -> GenerationResult:
        """Greedy decode ``gen`` tokens with a prefill/decode wall split.

        The decode phase times ``gen - 1`` steps (the first token falls
        out of prefill's last-position logits); ``block_until_ready``
        fences both phases so the split is honest about async dispatch.
        """
        gen = min(self.max_gen, gen or self.max_gen)
        t0 = time.perf_counter()
        logits, caches = self.prefill(batch)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        jax.block_until_ready(tok)
        t1 = time.perf_counter()
        toks = [tok]
        steps = 0
        for i in range(self.prompt_len, self.prompt_len + gen - 1):
            logits, caches = self.decode_step(tok, caches, i)
            tok = jnp.argmax(logits[:, 0], axis=-1)[:, None]
            toks.append(tok)
            steps += 1
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        return GenerationResult(
            tokens=jnp.concatenate(toks, axis=1),
            logits=logits[:, -1] if logits.ndim == 3 else logits,
            caches=caches, prefill_s=t1 - t0, decode_s=t2 - t1,
            decode_steps=steps)

    def warmup(self, batch: Optional[Dict] = None) -> None:
        """Compile prefill + step outside any timed region."""
        self.generate(batch if batch is not None
                      else self.make_prompt_batch(), gen=2)

    # -- checkpointable cache state ---------------------------------------

    @staticmethod
    def cache_state(caches: Any) -> Dict:
        """The KV/SSM caches as a plain dict pytree for checkpointing."""
        return jax.tree.map(lambda x: x, caches)

    def load_cache_state(self, template: Any, state: Dict) -> Any:
        """Re-adopt a restored cache pytree (shape/dtype-checked)."""
        flat_t, tdef = jax.tree.flatten(template)
        flat_s, sdef = jax.tree.flatten(state)
        if tdef != sdef:
            raise ValueError(f"cache structure mismatch: {tdef} vs {sdef}")
        for a, b in zip(flat_t, flat_s):
            if a.shape != b.shape or a.dtype != b.dtype:
                raise ValueError(
                    f"cache leaf mismatch: {a.shape}/{a.dtype} vs "
                    f"{b.shape}/{b.dtype}")
        return jax.tree.unflatten(tdef, flat_s)

    # -- analytics ---------------------------------------------------------

    def verdict(self, cfg: Optional[ModelConfig] = None) -> ModelVerdict:
        """The per-op model-scale verdict at this engine's (B, S, dtype).

        ``cfg`` defaults to the engine's own config; the serving path
        passes the *full-size* architecture so the verdict speaks at
        model scale while execution stays smoke-sized.
        """
        return model_verdict(cfg or self.cfg, self.max_batch, self.max_len,
                             dtype_bytes=jnp.dtype(self.dtype).itemsize)

    def traits(self, cfg: Optional[ModelConfig] = None):
        """Whole-step Eq. 2 traits (the record's analytic join fields)."""
        return step_traits(cfg or self.cfg, self.max_batch, self.max_len,
                           dtype_bytes=jnp.dtype(self.dtype).itemsize)
